"""Full-system elasticity edge battery: scale-to-zero experts + attention
client churn, all under the deterministic virtual clock.

The load-bearing contracts:

* **page-out is resource policy, never a model change** — evicting a cold
  expert removes only its replica slots; the primary shard stays
  addressable as the page-in source, so token streams are bitwise
  identical with ``cold_start_base = 0`` and the modeled penalty
  (``cold_start_base > 0``) only moves time;
* **page-in races an in-flight rebalance chunk safely** — a staged
  migration keeps applying while an expert pages out and back in;
* **client drain loses nothing** — a drained client stops admitting,
  finishes its in-flight async waves, then parks: zero failed requests
  and identical tokens;
* **hysteresis never flaps** — on a constant-rate uniform trace the
  controller settles and stops acting;
* the ``set_elastic`` scenario verb freezes/unfreezes every controller.
"""

import numpy as np
import pytest

from repro.configs import get_config
from repro.serving import (Cluster, ClusterConfig, EngineConfig, Request,
                           SamplingParams, Scenario, ServingEngine,
                           VirtualClock)
from repro.serving.autoscale import Autoscaler, AutoscalerConfig


@pytest.fixture(scope="module")
def cfg():
    return get_config("deepseek-r1").reduced()


def _ecfg(**kw):
    kw.setdefault("mode", "eaas")
    kw.setdefault("num_servers", 4)
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_seq", 64)
    kw.setdefault("n_redundant", 2)
    # drop-free dispatch: the identity pins require placement/routing to
    # never change which tokens reach their experts
    kw.setdefault("pool_tokens_per_client", 16)
    return EngineConfig(**kw)


def _engine(cfg, cold_start_base=0.0, **kw):
    return ServingEngine(cfg, _ecfg(**kw), seed=0,
                         clock=VirtualClock(cold_start_base=cold_start_base))


def _requests(cfg, n, max_new=6, seed=3):
    rng = np.random.default_rng(seed)
    return [Request(i, rng.integers(0, cfg.vocab_size, size=8).astype(
        np.int32), SamplingParams(max_new_tokens=max_new))
        for i in range(n)]


def _tokens(reqs):
    return {r.request_id: tuple(r.output_tokens) for r in reqs}


def _run(eng, cfg, n=8, on_step=None, **kw):
    reqs = _requests(cfg, n, **kw)
    for r in reqs:
        eng.submit(r)
    eng.run(max_steps=4000, on_step=on_step)
    return reqs


# --------------------------------------------------- scale-to-zero experts

def test_page_out_masks_replicas_keeps_primary(cfg):
    eng = _engine(cfg)
    _run(eng, cfg, n=4)
    E = cfg.moe.num_experts
    paged = eng.page_out_experts([0, 1])
    assert paged == [0, 1]
    pool = eng.pool
    assert pool.cold == {0, 1}
    assert pool.resident_fraction() == (E - 2) / E
    # replicas gone, primary-only rows remain as the page-in source
    assert not np.any(pool.redundant_table == 0)
    assert not np.any(pool.redundant_table == 1)
    for e in (0, 1):
        row = pool.smap.table[e]
        assert (row >= 0).sum() == 1
    # a cold expert's load is masked out of the next replica plan
    mapping, red = pool.plan()
    assert not np.any(red == 0) and not np.any(red == 1)


def test_cold_identity_and_penalty(cfg):
    """cold_start_base=0 -> bitwise identity; >0 -> same tokens, more
    time, cold starts charged."""
    def run(cold_start_base, page):
        eng = _engine(cfg, cold_start_base=cold_start_base)

        def on_step(e):
            if page and e.step_idx == 6:
                e.page_out_experts(list(range(cfg.moe.num_experts)))
        reqs = _run(eng, cfg, n=8, on_step=on_step)
        return eng, _tokens(reqs)

    base_eng, base_tok = run(0.0, page=False)
    free_eng, free_tok = run(0.0, page=True)
    paid_eng, paid_tok = run(5e-3, page=True)
    assert free_tok == base_tok                 # the tentpole identity pin
    assert paid_tok == base_tok                 # penalty moves time only
    assert free_eng.metrics.expert_page_outs > 0
    assert free_eng.metrics.cold_starts > 0     # traffic paged them back
    assert free_eng.metrics.cold_start_time == 0.0
    assert paid_eng.metrics.cold_start_time > 0.0
    assert paid_eng.clock > free_eng.clock
    # every touched expert paged back in resident
    assert paid_eng.pool.cold.isdisjoint(
        set(np.flatnonzero(paid_eng.pool.stats.ema)))


def test_page_in_race_with_inflight_rebalance_chunk(cfg):
    """An expert pages out and back in while a staged migration still has
    chunks pending — the chunk stream keeps applying and tokens match the
    undisturbed run."""
    import dataclasses
    from repro.serving import zipf_bias
    cfg16 = cfg.replace(moe=dataclasses.replace(cfg.moe, num_experts=16))

    def run(disturb):
        ecfg = EngineConfig(
            mode="eaas", num_servers=4, max_batch=8, max_seq=64,
            n_redundant=2, pool_tokens_per_client=32,
            charge_imbalance=True, rebalance_interval=0.02,
            rebalance_chunk=1)
        eng = ServingEngine(cfg16, ecfg, seed=0, clock=VirtualClock(
            decode_base=2e-4, decode_per_token=2e-3, expert_share=0.8,
            cold_start_base=1e-3))
        eng.set_skew(zipf_bias(16, 1.2, scale=1.0))
        hit = {"paged": False, "saw_pending": False}

        def on_step(e):
            if not disturb or hit["paged"]:
                return
            if e.rebalancer.migrating:    # a chunked migration is staged
                hit["saw_pending"] = True
                # page out the HOTTEST experts: the next decode step is
                # guaranteed to touch them, forcing the page-in while
                # migration chunks are still pending
                ema = e.pool.stats.ema
                hot = sorted(range(16), key=lambda x: -ema[x])[:4]
                if e.page_out_experts(hot):
                    hit["paged"] = True
        reqs = _run(eng, cfg16, n=16, max_new=24, seed=7,
                    on_step=on_step)
        return eng, hit, _tokens(reqs)

    clean_eng, _, clean_tok = run(disturb=False)
    race_eng, hit, race_tok = run(disturb=True)
    assert hit["saw_pending"] and hit["paged"]
    assert race_tok == clean_tok
    assert race_eng.metrics.expert_page_outs > 0
    assert race_eng.metrics.cold_starts > 0    # the hot set came back
    assert race_eng.metrics.completed == 16
    # consistency after the dust settles: every still-cold expert has no
    # replica column and a primary-only mapping row
    pool = race_eng.pool
    for e in pool.cold:
        assert (pool.smap.table[e] >= 0).sum() == 1


def test_pool_resize_resets_cold_set(cfg):
    eng = _engine(cfg)
    _run(eng, cfg, n=4)
    eng.page_out_experts([0, 1, 2])
    assert eng.pool.cold
    eng.scale_to(2)
    assert eng.pool.cold == set()      # resize re-provisions everything
    assert eng.pool.resident_fraction() == 1.0


# ------------------------------------------------------------ client churn

def _cluster(cfg, n, max_clients=None, exec_mode="async", **ekw):
    return Cluster(cfg, ClusterConfig(
        clients=n, engine=_ecfg(exec_mode=exec_mode, async_depth=2, **ekw),
        max_clients=max_clients), seed=0, clock_factory=VirtualClock)


def test_drain_with_inflight_async_waves_loses_nothing(cfg):
    """Drain mid-flight: the departing client finishes its pipelined
    waves, parks, and every token matches the no-drain run."""
    def run(drain):
        cl = _cluster(cfg, 2)
        reqs = _requests(cfg, 10, max_new=8)
        for r in reqs:
            cl.submit(r)
        state = {"drained": False}

        def on_step(c):
            if drain and not state["drained"] and c.step_idx >= 4:
                # client 1 must have waves in flight for the edge to bite
                if c.clients[1].tier is not None and c.client_alive[1]:
                    state["drained"] = c.drain_client(1)
        cl.run(max_steps=4000, on_step=on_step)
        return cl, state, _tokens(reqs)

    clean_cl, _, clean_tok = run(drain=False)
    drain_cl, state, drain_tok = run(drain=True)
    assert state["drained"]
    assert drain_cl.client_parked[1]
    assert drain_tok == clean_tok
    m = drain_cl.metrics
    assert m.failed_requests == 0
    assert m.completed == clean_cl.metrics.completed == 10
    assert m.client_drains == 1
    # the parked client's frozen clock no longer pins cluster time
    assert drain_cl.clock >= drain_cl.clients[0].clock


def test_drain_refuses_last_active_client(cfg):
    cl = _cluster(cfg, 2)
    assert cl.drain_client(1)
    assert not cl.drain_client(0)      # someone must keep serving
    assert cl.active_client_count() == 1


def test_spawn_revives_parked_then_builds_new(cfg):
    cl = _cluster(cfg, 2, max_clients=3)
    reqs = _requests(cfg, 6)
    for r in reqs:
        cl.submit(r)
    cl.run(max_steps=4000)
    assert cl.drain_client(1)
    cl.step()                          # idle drain parks immediately
    assert cl.client_parked[1]
    assert cl.spawn_client() == 1      # lowest parked index revives first
    assert not cl.client_parked[1]
    i = cl.spawn_client()              # fresh engine joins the ring
    assert i == 2
    assert len(cl.clients) == 3
    assert cl.router.n_clients == 3
    assert cl.clients[2]._shared_pool  # shares the one expert tier
    assert cl.spawn_client() is None   # max_clients cap
    more = _requests(cfg, 6, seed=9)
    for r in more:
        r.request_id += 50
        cl.submit(r)
    cl.run(max_steps=4000)
    assert cl.metrics.failed_requests == 0
    assert sum(len(t) for t in _tokens(more).values()) > 0


# -------------------------------------------------------- controller loop

def test_autoscaler_no_flap_on_constant_rate(cfg):
    """Constant-rate uniform traffic: after the initial convergence the
    controller goes quiet — no server oscillation, no client churn, no
    expert paging (uniform share >= the idle threshold)."""
    cl = _cluster(cfg, 2, max_clients=2)
    scaler = Autoscaler(AutoscalerConfig(
        rate_per_server=30.0, min_servers=1, max_servers=4,
        # a long-enough rate window plus the down_headroom deadband is
        # what keeps Poisson arrival noise from flapping the size
        window=0.5, cooldown=0.05,
        rate_per_client=30.0, min_clients=1, max_clients=2))
    sc = (Scenario(horizon=0.8, seed=11, prompt_len=8, max_new=6,
                   vocab=cfg.vocab_size).poisson(rate=20.0)
          .autoscale(scaler))
    sc.run(cl, max_steps=20_000)
    m = cl.metrics
    # the pool-size sequence settles monotonically: no value is ever
    # revisited after leaving it (A-B-A flapping)
    sizes = [actual for _, _, _, actual in scaler.trace]
    compact = [s for i, s in enumerate(sizes)
               if i == 0 or s != sizes[i - 1]]
    assert len(compact) == len(set(compact)), compact
    assert compact[-1] < 4                     # it did scale down, once
    # client decisions likewise settle to one steady value
    wants = [w for _, w, _ in scaler.client_trace]
    assert len(set(wants[len(wants) // 2:])) <= 1
    assert m.client_spawns + m.client_drains <= 1


def test_page_protect_window_blocks_flap(cfg):
    """Hysteresis at the expert level: a freshly paged-in expert is
    protected from paging back out until ``page_in_protect`` elapses."""
    eng = _engine(cfg)
    _run(eng, cfg, n=2)
    pool = eng.pool
    E = cfg.moe.num_experts
    pool.stats.ema = np.ones(E)
    pool.stats.ema[0] = 1e-3                   # expert 0: cold by traffic
    scaler = Autoscaler(AutoscalerConfig(
        rate_per_server=1e9, expert_idle_fraction=0.5,
        page_in_protect=0.5))
    t = eng.clock
    assert 0 in scaler._pageable_experts(eng, t)
    eng.page_out_experts([0])
    assert 0 not in scaler._pageable_experts(eng, t)   # already cold
    pool.page_in_expert(0, t)
    assert 0 not in scaler._pageable_experts(eng, t + 0.4)  # protected
    assert 0 in scaler._pageable_experts(eng, t + 0.6)      # expired


def test_set_elastic_verb_freezes_and_resumes(cfg):
    def run(freeze):
        cl = _cluster(cfg, 2, max_clients=2)
        scaler = Autoscaler(AutoscalerConfig(
            rate_per_server=12.0, min_servers=1, max_servers=4,
            window=0.1, cooldown=0.1,
            rate_per_client=20.0, min_clients=1, max_clients=2,
            expert_idle_fraction=0.5, page_in_protect=0.2))
        sc = (Scenario(horizon=1.0, seed=1, prompt_len=8, max_new=8,
                       vocab=cfg.vocab_size)
              .diurnal(40, amplitude=0.9, period=1.0)
              .zipf_skew(1.2, scale=3.0)
              .autoscale(scaler))
        if freeze:
            sc.set_elastic(0.0, False)
        res = sc.run(cl, max_steps=20_000)
        return cl, _tokens(res.requests)

    live_cl, live_tok = run(freeze=False)
    froz_cl, froz_tok = run(freeze=True)
    # frozen controllers: statically provisioned run, to the token
    assert froz_cl.metrics.expert_page_outs == 0
    assert froz_cl.metrics.client_drains == 0
    assert froz_cl.pool.num_servers == 4
    assert live_cl.metrics.expert_page_outs > 0
    assert froz_tok == live_tok        # policy freeze is not a model change
    assert froz_cl.metrics.resource_seconds \
        > live_cl.metrics.resource_seconds


def test_set_elastic_requires_autoscaler(cfg):
    cl = _cluster(cfg, 1)
    sc = (Scenario(horizon=0.05, seed=1, vocab=cfg.vocab_size)
          .poisson(rate=40).set_elastic(0.0, False))
    with pytest.raises(ValueError):
        sc.run(cl, max_steps=2000)


def test_resource_trace_windowed_integration(cfg):
    cl = _cluster(cfg, 2)
    reqs = _requests(cfg, 6)
    for r in reqs:
        cl.submit(r)
    cl.run(max_steps=4000)
    m = cl.metrics
    # static fleet: units constant at clients + servers
    assert m.resource_trace[0] == (0.0, 2 + 4)
    total = m.wall_time * 6
    assert m.resource_seconds == pytest.approx(total, rel=1e-6)
    half = m.resource_seconds_in(0.0, m.wall_time / 2)
    assert half == pytest.approx(total / 2, rel=1e-6)
