"""Scenario harness tour: script a traffic + fault + scaling timeline and
replay it deterministically against the serving engine (virtual clock).

Recreates the paper's two headline timelines in one run:

* Fig. 10 fault curve — a server dies and recovers mid-traffic; EAAS dips
  by the lost compute share instead of stalling;
* Fig. 11 elasticity — traffic halves and the autoscaler walks the expert
  pool down to the ``provision()`` target, printing the resource saving.

Run:  PYTHONPATH=src python examples/scenario_autoscale.py
Same seed ⇒ identical output, every run, on any machine.
"""

from repro.configs import get_config
from repro.core.elastic import provision
from repro.serving import (Autoscaler, AutoscalerConfig, EngineConfig,
                           Scenario, ServingEngine, VirtualClock)


def main():
    cfg = get_config("deepseek-r1").reduced()

    # ---- Fig. 10: fault timeline ---------------------------------------
    print("== fault timeline (EAAS, server 1 dies at t=0.1, back at t=0.2)")
    ecfg = EngineConfig(mode="eaas", num_servers=4, max_batch=4, max_seq=64,
                        n_redundant=2)
    eng = ServingEngine(cfg, ecfg, clock=VirtualClock())
    sc = (Scenario(horizon=0.3, seed=0, max_new=8, vocab=cfg.vocab_size)
          .poisson(rate=300)
          .fail(rank=1, t=0.1)
          .recover(rank=1, t=0.2)
          .rebalance(t=0.25))
    res = sc.run(eng)
    for t, thr in res.metrics.throughput_curve(bin_width=0.05):
        bar = "#" * int(thr / 25)
        print(f"  t={t:4.2f}s  {thr:7.1f} tok/s  {bar}")
    print(f"  summary: {res.summary()}")

    # ---- Fig. 11: autoscaling timeline ---------------------------------
    print("== autoscale timeline (traffic 300 -> 80 req/s at t=0.6)")
    ecfg = EngineConfig(mode="eaas", num_servers=8, max_batch=4, max_seq=64,
                        n_redundant=1)
    eng = ServingEngine(cfg, ecfg, clock=VirtualClock())
    asc = Autoscaler(AutoscalerConfig(rate_per_server=40, min_servers=1,
                                      max_servers=8, window=0.2,
                                      cooldown=0.1))
    sc = (Scenario(horizon=1.2, seed=0, max_new=4, vocab=cfg.vocab_size)
          .poisson(rate=300)
          .set_rate(t=0.6, rate=80)
          .autoscale(asc))
    res = sc.run(eng)
    for e in res.metrics.events:
        if e["event"] == "scale":
            print(f"  t={e['t']:.3f}s  scale {e['from']} -> {e['to']}")
    target = provision(80, rate_per_server=40, granularity=1)
    final = eng.pool.num_servers
    print(f"  final pool: {final} servers (provision target {target}); "
          f"saving vs static 8: {100 * (1 - final / 8):.1f}%")


if __name__ == "__main__":
    main()
