"""KV caches and recurrent states for serving.

:class:`KVCache` — per-layer (batch, slots, kv_heads, head_dim) buffers with
a per-sequence length counter.  Sliding-window layers allocate only
``window`` slots and write round-robin.  ``window`` is a *static* pytree
field so stacked caches can ride ``lax.scan`` over layers.

All update ops are functional (return a new cache) so they can live inside
jitted ``serve_step``s and be donated.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclass
class KVCache:
    """One layer's cache.  k/v: (batch, slots, kv_heads, head_dim)."""

    k: jax.Array
    v: jax.Array
    # number of tokens already written per sequence: (batch,) int32
    length: jax.Array
    # ring buffer (sliding window) if window > 0, else linear — STATIC
    window: int = field(default=0, metadata=dict(static=True))


def init_kv_cache(batch: int, max_seq: int, kv_heads: int, head_dim: int,
                  dtype=jnp.bfloat16, window: int = 0) -> KVCache:
    slots = min(window, max_seq) if window else max_seq
    return KVCache(
        k=jnp.zeros((batch, slots, kv_heads, head_dim), dtype),
        v=jnp.zeros((batch, slots, kv_heads, head_dim), dtype),
        length=jnp.zeros((batch,), jnp.int32),
        window=window,
    )


def kv_cache_spec(batch: int, max_seq: int, kv_heads: int, head_dim: int,
                  dtype=jnp.bfloat16, window: int = 0) -> KVCache:
    """ShapeDtypeStruct twin of :func:`init_kv_cache` (for the dry-run)."""
    slots = min(window, max_seq) if window else max_seq
    sds = jax.ShapeDtypeStruct
    return KVCache(
        k=sds((batch, slots, kv_heads, head_dim), dtype),
        v=sds((batch, slots, kv_heads, head_dim), dtype),
        length=sds((batch,), jnp.int32),
        window=window,
    )


def append_decode(cache: KVCache, k_new: jax.Array, v_new: jax.Array) -> KVCache:
    """Append ONE token per sequence.  k_new/v_new: (batch, 1, kv_heads, hd).

    Implemented as a vmapped dynamic-update-slice (not a gather-scatter):
    GSPMD keeps the batch dim partitioned through DUS, whereas the explicit-
    index scatter forced an all-gather of the cache every layer.
    """
    slots = cache.k.shape[1]
    idx = cache.length % slots if cache.window else cache.length

    def upd(c, new, i):                  # (slots, KV, hd), (KV, hd), scalar
        return jax.lax.dynamic_update_slice_in_dim(c, new[None], i, axis=0)

    k = jax.vmap(upd)(cache.k, k_new[:, 0], idx)
    v = jax.vmap(upd)(cache.v, v_new[:, 0], idx)
    return KVCache(k=k, v=v, length=cache.length + 1, window=cache.window)


def write_prefill(cache: KVCache, k: jax.Array, v: jax.Array) -> KVCache:
    """Write a full prompt (batch, seq, kv_heads, hd) starting at position 0."""
    seq = k.shape[1]
    slots = cache.k.shape[1]
    if cache.window and seq > slots:
        # only the trailing `window` tokens are retained; keep ring phase
        k_tail, v_tail = k[:, -slots:], v[:, -slots:]
        pos = (jnp.arange(seq - slots, seq) % slots)
        ck = cache.k.at[:, pos].set(k_tail)
        cv = cache.v.at[:, pos].set(v_tail)
    else:
        ck = jax.lax.dynamic_update_slice_in_dim(cache.k, k, 0, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache.v, v, 0, axis=1)
    length = jnp.full_like(cache.length, seq)
    return KVCache(k=ck, v=cv, length=length, window=cache.window)


def write_chunk(cache: KVCache, k: jax.Array, v: jax.Array,
                start) -> KVCache:
    """Write a prompt *chunk* (batch, chunk, kv_heads, hd) at position
    ``start`` (scalar int32, may be traced).  Linear caches only — chunked
    prefill is gated off for sliding-window layers by the caller."""
    assert cache.window == 0, "write_chunk needs a linear cache"
    seq = k.shape[1]
    ck = jax.lax.dynamic_update_slice_in_dim(cache.k, k, start, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache.v, v, start, axis=1)
    length = jnp.full_like(cache.length, start + seq)
    return KVCache(k=ck, v=cv, length=length, window=cache.window)


def valid_mask(cache: KVCache) -> jax.Array:
    """(batch, slots) bool — which cache slots hold valid tokens."""
    slots = cache.k.shape[1]
    pos = jnp.arange(slots)[None, :]
    if cache.window:
        n_valid = jnp.minimum(cache.length, slots)[:, None]
        return pos < jnp.broadcast_to(n_valid, (cache.k.shape[0], slots))
    return pos < cache.length[:, None]
