"""GQA attention: training/prefill (full, causal, optional sliding window),
decode against a KV cache, and cross-attention (whisper decoder).

Pure per-shard math; distribution (TP over heads, DP over batch, SP over the
cache for long contexts) is applied by the launch layer via shardings.
RoPE is applied to q/k *before* the keys are cached, so cached keys are
already rotated (standard practice; makes ring-buffer windows trivial).
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import kv_cache as kvc
from repro.models.common import dense_init, softcap
from repro.models.rope import apply_rope, mrope_cos_sin, rope_cos_sin, text_mrope_positions

NEG_INF = -1e30


# --------------------------------------------------------------------- init

def init_attention(key, cfg: ModelConfig, cross: bool = False) -> Dict:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d, h * hd, dt),
        "wk": dense_init(ks[1], d, kv * hd, dt),
        "wv": dense_init(ks[2], d, kv * hd, dt),
        "wo": dense_init(ks[3], h * hd, d, dt),
    }


# ------------------------------------------------------------------ helpers

def _split_heads(x: jax.Array, n: int, hd: int) -> jax.Array:
    return x.reshape(x.shape[:-1] + (n, hd))


def _rope_for(cfg: ModelConfig, positions: jax.Array, mrope_positions=None):
    if cfg.mrope_sections is not None:
        pos3 = (mrope_positions if mrope_positions is not None
                else text_mrope_positions(positions))
        return mrope_cos_sin(pos3, cfg.head_dim, cfg.rope_theta,
                             cfg.mrope_sections)
    return rope_cos_sin(positions, cfg.head_dim, cfg.rope_theta)


def _gqa_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q: (b, sq, H, hd), k: (b, sk, KV, hd) -> (b, KV, G, sq, sk) fp32."""
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    q = q.reshape(b, sq, kvh, g, hd)
    return jnp.einsum("bqkgh,bskh->bkgqs", q.astype(jnp.float32),
                      k.astype(jnp.float32))


def _gqa_out(p: jax.Array, v: jax.Array) -> jax.Array:
    """p: (b, KV, G, sq, sk), v: (b, sk, KV, hd) -> (b, sq, H, hd)."""
    b, kvh, g, sq, sk = p.shape
    out = jnp.einsum("bkgqs,bskh->bqkgh", p, v.astype(jnp.float32))
    return out.reshape(b, sq, kvh * g, v.shape[-1])


# ------------------------------------------------------- full (train/prefill)

# sequences at or above this length use the q-block-chunked (flash-style)
# path so (S, S) score matrices never materialize
CHUNKED_ATTN_THRESHOLD = 2048


def full_attention(params: Dict, cfg: ModelConfig, x: jax.Array,
                   positions: jax.Array, *, is_local: bool = False,
                   mrope_positions=None, causal: bool = True,
                   return_kv: bool = False, q_blocks: int = 16,
                   unroll: bool = False):
    """Self-attention over the full sequence (train / prefill).

    x: (batch, seq, d_model); positions: (batch, seq) or (seq,) int32.
    ``is_local`` applies cfg.sliding_window masking (gemma3 local layers);
    ``causal=False`` gives the bidirectional encoder variant (whisper);
    ``return_kv`` additionally returns the rotated (k, v) for cache fills.
    Long sequences run the chunked path: a scan over q blocks, each block
    rematerialized in backward (flash-attention memory profile).
    """
    b, s, d = x.shape
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = _split_heads(x @ params["wq"], h, hd)
    k = _split_heads(x @ params["wk"], kvh, hd)
    v = _split_heads(x @ params["wv"], kvh, hd)

    if positions.ndim == 1:
        positions = jnp.broadcast_to(positions[None], (b, s))
    cos, sin = _rope_for(cfg, positions, mrope_positions)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    if s >= CHUNKED_ATTN_THRESHOLD and s % q_blocks == 0:
        out = _chunked_core(cfg, q, k, v, positions, is_local=is_local,
                            causal=causal, q_blocks=q_blocks, unroll=unroll)
    else:
        out = _dense_core(cfg, q, k, v, positions, positions,
                          is_local=is_local, causal=causal)
    out = out.reshape(b, s, h * hd).astype(x.dtype) @ params["wo"]
    if return_kv:
        return out, (k, v)
    return out


def _dense_core(cfg, q, k, v, q_pos, k_pos, *, is_local, causal):
    """Reference path: materialized scores.  Returns (b, sq, H, hd) fp32."""
    hd = cfg.head_dim
    scores = _gqa_scores(q, k) / math.sqrt(hd)
    if cfg.attn_logit_softcap:
        scores = softcap(scores, cfg.attn_logit_softcap)
    i = q_pos[:, None, None, :, None]
    j = k_pos[:, None, None, None, :]
    mask = (j <= i) if causal else jnp.broadcast_to(
        jnp.bool_(True), (j <= i).shape)
    if is_local and cfg.sliding_window:
        mask &= (i - j) < cfg.sliding_window
    scores = jnp.where(mask, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    return _gqa_out(p, v)


def _chunked_core(cfg, q, k, v, positions, *, is_local, causal,
                  q_blocks: int, unroll: bool):
    """Flash-style: scan over q blocks against full K/V; each block body is
    checkpointed so backward recomputes its scores instead of saving them."""
    b, s, h, hd = q.shape
    bq = s // q_blocks
    qb = q.reshape(b, q_blocks, bq, h, hd).swapaxes(0, 1)     # (nq,b,bq,h,hd)
    pb = positions.reshape(b, q_blocks, bq).swapaxes(0, 1)

    def body(_, inp):
        qi, pi = inp
        out = _dense_core(cfg, qi, k, v, pi, positions,
                          is_local=is_local, causal=causal)
        return None, out

    _, outs = jax.lax.scan(jax.checkpoint(body), None, (qb, pb),
                           unroll=unroll)
    return outs.swapaxes(0, 1).reshape(b, s, h, hd)


# ------------------------------------------------------------ chunk prefill

def chunk_attention(params: Dict, cfg: ModelConfig, x: jax.Array,
                    cache, positions: jax.Array, *,
                    mrope_positions=None):
    """Prefill one prompt *chunk* against the cache (chunked prefill).

    x: (batch, chunk, d_model); positions: (chunk,) global token positions
    (``start + arange(chunk)``).  The chunk's rotated K/V are written into
    the cache at ``positions[0]`` and the chunk's queries attend over every
    cached position ``<=`` their own — earlier chunks included — so the
    result matches a single full-prompt prefill (slots beyond the causal
    frontier are masked; masked lanes contribute exact zeros).

    ``cache`` may be a dense :class:`~repro.models.kv_cache.KVCache` or a
    :class:`~repro.models.kv_cache.PagedKVCache`; the paged branch writes
    through the block table and attends over the gathered view — including
    prefix-cache blocks written by an *earlier* request, which is how a
    prefix hit lets the chunk start mid-prompt.
    """
    b, s, d = x.shape
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = _split_heads(x @ params["wq"], h, hd)
    k = _split_heads(x @ params["wk"], kvh, hd)
    v = _split_heads(x @ params["wv"], kvh, hd)

    pos_b = jnp.broadcast_to(positions[None], (b, s))
    cos, sin = _rope_for(cfg, pos_b, mrope_positions)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    if isinstance(cache, kvc.PagedKVCache):
        cache = kvc.paged_write_chunk(cache, k, v, positions[0])
        ck, cv = kvc.gather_blocks(cache)
    else:
        cache = kvc.write_chunk(cache, k, v, positions[0])
        ck, cv = cache.k, cache.v
    slots = ck.shape[1]
    k_pos = jnp.broadcast_to(jnp.arange(slots, dtype=jnp.int32)[None],
                             (b, slots))
    out = _dense_core(cfg, q, ck, cv, pos_b, k_pos,
                      is_local=False, causal=True)
    out = out.reshape(b, s, h * hd).astype(x.dtype) @ params["wo"]
    return out, cache


# ------------------------------------------------------------------- decode

def decode_attention(params: Dict, cfg: ModelConfig, x: jax.Array,
                     cache, *, is_local: bool = False,
                     mrope_positions=None):
    """One-token decode: x (batch, 1, d_model) against the cache.

    ``cache`` may be dense or paged (:class:`~repro.models.kv_cache
    .PagedKVCache`); the paged branch appends through the block table and
    attends over the gathered view — lane-for-lane the dense math when the
    view width equals the dense slot count, so greedy outputs match
    bitwise.  (Sliding-window ``is_local`` layers are dense-only; the
    serving engine pages the uniform decoder family.)

    Returns (output (batch, 1, d_model), updated cache).
    """
    b = x.shape[0]
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = _split_heads(x @ params["wq"], h, hd)        # (b,1,H,hd)
    k = _split_heads(x @ params["wk"], kvh, hd)
    v = _split_heads(x @ params["wv"], kvh, hd)

    positions = cache.length[:, None]                # (b,1) current position
    cos, sin = _rope_for(cfg, positions, mrope_positions)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    if isinstance(cache, kvc.PagedKVCache):
        cache = kvc.paged_append_decode(cache, k, v)
        ck, cv = kvc.gather_blocks(cache)
        mask = kvc.paged_valid_mask(cache)[:, None, None, None, :]
    else:
        cache = kvc.append_decode(cache, k, v)
        ck, cv = cache.k, cache.v
        mask = kvc.valid_mask(cache)[:, None, None, None, :]
    scores = _gqa_scores(q, ck) / math.sqrt(hd)        # (b,KV,G,1,slots)
    if cfg.attn_logit_softcap:
        scores = softcap(scores, cfg.attn_logit_softcap)
    scores = jnp.where(mask, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = _gqa_out(p, cv).astype(x.dtype)
    return out.reshape(b, 1, h * hd) @ params["wo"], cache


def decode_attention_partial(params: Dict, cfg: ModelConfig, q: jax.Array,
                             cache: kvc.KVCache
                             ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Flash-decode partial pass over a cache *shard* (sequence parallelism).

    q: (b, 1, H, hd) already rotated.  Returns (acc, max, lse) so shards can
    be combined with a small cross-shard softmax reduction:
        acc: (b, 1, H, hd) unnormalized sum of p*v, m: (b,1,H,1), l: (b,1,H,1)
    """
    hd = cfg.head_dim
    scores = _gqa_scores(q, cache.k) / math.sqrt(hd)   # (b,KV,G,1,slots)
    mask = kvc.valid_mask(cache)[:, None, None, None, :]
    scores = jnp.where(mask, scores, NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    acc = _gqa_out(p, cache.v)                         # (b,1,H,hd) fp32
    b, _, h, _ = q.shape
    m = m.reshape(b, 1, h, 1)
    l = l.reshape(b, 1, h, 1)
    return acc, m, l


def combine_partial_attention(acc, m, l, axis_name: str):
    """Combine flash-decode partials across a shard_map axis."""
    g_m = jax.lax.pmax(m, axis_name)
    scale = jnp.exp(m - g_m)
    num = jax.lax.psum(acc * scale, axis_name)
    den = jax.lax.psum(l * scale, axis_name)
    return num / jnp.maximum(den, 1e-30)


# ------------------------------------------------------------- cross-attn

def init_cross_attention(key, cfg: ModelConfig) -> Dict:
    return init_attention(key, cfg, cross=True)


def cross_attention(params: Dict, cfg: ModelConfig, x: jax.Array,
                    enc_out: jax.Array) -> jax.Array:
    """Decoder cross-attention (whisper): queries from x, k/v from enc_out."""
    b, s, d = x.shape
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    k = _split_heads(enc_out @ params["wk"], kvh, hd)
    v = _split_heads(enc_out @ params["wv"], kvh, hd)
    return cross_attention_cached(params, cfg, x, k, v)


def cross_attention_cached(params: Dict, cfg: ModelConfig, x: jax.Array,
                           k: jax.Array, v: jax.Array) -> jax.Array:
    """Cross-attention against precomputed encoder K/V (decode path)."""
    b, s, d = x.shape
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = _split_heads(x @ params["wq"], h, hd)
    scores = _gqa_scores(q, k) / math.sqrt(hd)
    p = jax.nn.softmax(scores, axis=-1)
    out = _gqa_out(p, v).astype(x.dtype)
    return out.reshape(b, s, h * hd) @ params["wo"]
