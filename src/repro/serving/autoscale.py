"""Elastic autoscaling control loop (paper §5.3, Fig. 11).

EAAS scales the expert-service tier one server at a time; monolithic EP only
in whole communication-group multiples.  The :class:`Autoscaler` watches the
arrival rate (sliding window over submitted requests) plus queue depth and
drives ``engine.scale_to`` toward the :func:`repro.core.elastic.provision`
target at its configured granularity — the 37.5% saving in the paper is
exactly the gap between granularity 1 and granularity 64 under a traffic
drop.

The loop is pure host-side policy over engine observables: deterministic
under a virtual clock, and trivially swappable (subclass and override
:meth:`desired_servers`).
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Deque, List, Optional, Tuple

from repro.core.elastic import provision


@dataclass
class AutoscalerConfig:
    rate_per_server: float            # request/s one expert server sustains
    min_servers: int = 1
    max_servers: int = 8
    granularity: int = 1              # 1 = EAAS; group size = monolithic EP
    window: float = 0.25              # arrival-rate estimation window (s)
    cooldown: float = 0.2             # min time between scaling actions (s)
    queue_per_server: float = 0.0     # extra server per this much queue
                                      # backlog (0 disables queue pressure)
    # extra server per this many *unprefilled prompt tokens* (queued +
    # mid-chunk backlog) — with chunked prefill a deep prompt backlog is
    # visible before it converts into queue depth (0 disables)
    prefill_tokens_per_server: float = 0.0
    # scale up while the KV block pool's free fraction sits below this
    # threshold (0 disables).  Memory pressure precedes admission stalls:
    # the pool drains *before* the queue backs up, so this knob fires a
    # step earlier than queue/backlog pressure — the paper's point that
    # attention-tier memory, not expert FLOPs, caps admitted traffic.
    kv_pressure_threshold: float = 0.0


class Autoscaler:
    """Traffic-driven pool resizing: observe arrivals, converge on
    ``provision(rate)`` snapped to a feasible pool size."""

    def __init__(self, cfg: AutoscalerConfig):
        self.cfg = cfg
        self._arrivals: Deque[float] = deque()
        self._last_action = -float("inf")
        # (t, observed rate, desired, actual) decision trace
        self.trace: List[Tuple[float, float, int, int]] = []

    # ------------------------------------------------------------- signals
    def observe_arrival(self, t: float) -> None:
        self._arrivals.append(t)

    def observed_rate(self, t: float) -> float:
        w = self.cfg.window
        while self._arrivals and self._arrivals[0] < t - w:
            self._arrivals.popleft()
        return len(self._arrivals) / max(w, 1e-9)

    # -------------------------------------------------------------- policy
    def desired_servers(self, t: float, queue_depth: int,
                        prefill_backlog: int = 0,
                        kv_free_fraction: float = 1.0) -> int:
        c = self.cfg
        n = provision(self.observed_rate(t), c.rate_per_server,
                      c.granularity)
        if c.queue_per_server > 0 and queue_depth > 0:
            n += int(queue_depth / c.queue_per_server)
        if c.prefill_tokens_per_server > 0 and prefill_backlog > 0:
            n += int(prefill_backlog / c.prefill_tokens_per_server)
        if (c.kv_pressure_threshold > 0
                and kv_free_fraction < c.kv_pressure_threshold):
            n += 1
        return max(c.min_servers, min(c.max_servers, n))

    def step(self, engine, t: float) -> Optional[int]:
        """One control iteration; returns the new pool size if it scaled."""
        if engine.pool is None:
            return None
        if t < self.cfg.window:        # warm-up: the rate estimate is not
            return None                # meaningful before one full window
        # coordinate with live rebalancing: expert-level replication acts
        # first (cheap, no recompile) — hold server-count scaling while a
        # migration is in flight or inside the shared placement cooldown
        reb = getattr(engine, "rebalancer", None)
        if reb is not None and reb.migrating:
            return None
        if (t - getattr(engine, "last_placement_change", float("-inf"))
                < self.cfg.cooldown):
            return None
        # engine-level signal methods so one policy loop drives both a
        # standalone engine and a Cluster (which aggregates over clients)
        backlog = 0
        if self.cfg.prefill_tokens_per_server > 0:
            backlog = engine.pending_prefill_tokens()
        kv_free = 1.0
        if self.cfg.kv_pressure_threshold > 0:
            kv_free = engine.kv_free_fraction()
        want = self.desired_servers(t, len(engine.queue), backlog, kv_free)
        # snap up to the nearest pool size the expert layout supports
        feasible = [n for n in engine.pool.feasible_counts()
                    if n <= self.cfg.max_servers]
        snapped = next((n for n in feasible if n >= want),
                       feasible[-1] if feasible else want)
        have = engine.pool.num_servers
        self.trace.append((t, self.observed_rate(t), snapped, have))
        if snapped == have or t - self._last_action < self.cfg.cooldown:
            return None
        engine.scale_to(snapped)
        self._last_action = t
        return snapped
